"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per benchmark plus the full
JSON rows to runs/bench_results.json.  Benchmarks with per-tenant runtime
accounting also emit schema-versioned ``runs/*_timeline.json`` artifacts
(core/obs.py, docs/observability.md) next to the bench JSON.

Sections:
  fig1      — technique-removal latency/throughput (paper Fig. 1)
  fig3/fig4 — CoRD overhead matrix & relative throughput (Figs. 3-4)
  window    — CQ-runtime bandwidth vs. sender-window depth (RC + UD)
  credits   — credit flow-control ablation (stall counters)
  serve     — gang vs continuous-slot serving (tok/s, TTFT, compiles)
  fig5      — system-A preset (Fig. 5)
  fig6      — NPB suite bypass/cord/socket (Fig. 6)
  kernels   — Pallas kernel correctness + XLA timings
  roofline  — dry-run roofline terms (if runs/dryrun is populated)

Requires >=8 CPU devices: the driver re-execs itself with the XLA flag if
needed, so ``PYTHONPATH=src python -m benchmarks.run [--fast]`` suffices.
"""

from __future__ import annotations

import json
import os
import sys

from benchmarks._bootstrap import ensure_host_devices

ensure_host_devices(8, module="benchmarks.run")


def dry_run() -> None:
    """CI smoke: build the measured paths and execute a minimal slice of
    each — perftest ping-pong over the verbs layer, one NPB kernel in
    bypass+cord, and a per-tenant counter timeline over repeated windowed
    transfers, asserting the emitted artifact is well-formed — without
    the full figure sweeps."""
    import jax
    import jax.numpy as jnp

    from benchmarks import npb, perftest
    from repro.core.obs import CounterTimeline

    mesh2 = perftest.make_mesh2()
    dp = perftest._dp("cord", emulate=True, mesh=mesh2)
    lat = perftest.pingpong_latency_us(mesh2, dp, dp, 1024, iters=4)
    print(json.dumps({"table": "dryrun", "pingpong_us": round(lat, 2),
                      "pipeline": list(dp.pipeline.stage_names)}))
    gbps, rate, stats = perftest.windowed_throughput(
        mesh2, dp, dp, 1024, window=4, n_msgs=8)
    print(json.dumps({"table": "dryrun", "windowed_gbps": round(gbps, 3),
                      **stats}))

    # timeline smoke: several windowed transfers, each from a fresh
    # runtime state (build_windowed's body already allreduce_state-sums
    # its state over the mesh — feeding that aggregate back in would
    # re-psum it every call), with host-side accumulation into cumulative
    # per-tenant totals between calls; assert the saved artifact
    # round-trips as schema-valid with an honest, constant-work rate
    # series per tenant
    fn, _ = perftest.build_windowed(mesh2, dp, dp, 1024, n_msgs=8, window=4)
    msgs = jnp.zeros((2, 8, 1024), jnp.uint8)
    rt0 = dp.runtime_init()
    totals: dict[str, dict[str, float]] = {}
    timeline = CounterTimeline(source="bench-dryrun")
    for i in range(1, 5):
        _, _, rt = jax.block_until_ready(fn(msgs, rt0))
        for tenant, ctrs in dp.runtime_report(rt).items():
            acc = totals.setdefault(tenant, dict.fromkeys(ctrs, 0.0))
            for k, v in ctrs.items():
                acc[k] = max(acc[k], v) if k == "cq_depth" else acc[k] + v
        timeline.snapshot(i, {t: dict(a) for t, a in totals.items()})
    path = timeline.save("runs/dryrun_timeline.json")
    doc = CounterTimeline.load(path)             # schema validation
    rates = doc["rates"][dp.tenant]
    assert len(rates["ops_s"]) == 3 and all(rates["ops_s"]), rates
    # identical transfers must account identical work per window — a
    # doubling series here means state got re-aggregated somewhere
    ops = [s["tenants"][dp.tenant]["ops"] for s in doc["samples"]]
    deltas = [b - a for a, b in zip(ops, ops[1:])]
    assert deltas and all(d == deltas[0] for d in deltas), ops
    print(json.dumps({"table": "dryrun", "timeline": path,
                      "samples": len(doc["samples"]),
                      "ops_s_last": round(rates["ops_s"][-1], 1)}))

    for row in npb.run_all(benches=("EP",), modes=("bypass", "cord")):
        print(json.dumps(row))
    print("dry-run ok")


def main() -> None:
    if "--dry-run" in sys.argv:
        dry_run()
        return
    fast = "--fast" in sys.argv
    rows = []

    print("# perftest (figs 1, 3, 4, 5)")
    from benchmarks import perftest
    rows += perftest.run_all(fast=fast)

    print("# NPB (fig 6)")
    from benchmarks import npb
    rows += npb.run_all()

    print("# serve (gang vs continuous slots)")
    from benchmarks import serve
    rows += serve.run_all(fast=fast)

    print("# kernels")
    from benchmarks import kernels_bench
    rows += kernels_bench.run_all()

    if os.path.isdir("runs/dryrun") and os.listdir("runs/dryrun"):
        print("# roofline (from dry-run artifacts)")
        from benchmarks import roofline
        roof = roofline.run_all(use_hlo=not fast)
        rows += [{"table": "roofline", **r} for r in roof]

    os.makedirs("runs", exist_ok=True)
    with open("runs/bench_results.json", "w") as f:
        json.dump(rows, f, indent=1, default=str)

    # CSV summary: name,us_per_call,derived
    print("name,us_per_call,derived")
    for r in rows:
        tab = r.get("table", "?")
        if tab == "fig1":
            print(f"fig1/{r['variant']}/{r['bytes']}B,{r['latency_us']},"
                  f"gbps={r['gbps']}")
        elif tab in ("fig3", "fig5_lat"):
            print(f"{tab}/{r['transport']}/{r['op']}/{r['client']}-"
                  f"{r['server']},{r['latency_us']},"
                  f"overhead_us={r['overhead_us']}")
        elif tab in ("fig4", "fig5_bw"):
            print(f"{tab}/{r['transport']}/{r['op']}/{r['bytes']}B,,"
                  f"rel_tput={r['rel_throughput']}")
        elif tab == "window":
            print(f"window/{r['transport']}/{r['op']}/{r['bytes']}B/"
                  f"w{r['window']},,gbps={r['gbps']} cq={r['cq_hwm']}")
        elif tab == "credits":
            print(f"credits/{r['bytes']}B/w{r['window']}/"
                  f"c{r['rx_credits']},,gbps={r['gbps']} "
                  f"stalls={r['stalls']}")
        elif tab == "serve":
            print(f"serve/{r['scheduler']}/q{r['queue_depth']},,"
                  f"tok_s={r['tok_s']} ttft_ms={r['ttft_ms_mean']} "
                  f"compiles={r['decode_compiles']}")
        elif tab == "fig6":
            print(f"fig6/{r['bench']}/{r['mode']},{r['ms'] * 1e3},"
                  f"rel={r['rel_runtime']}")
        elif tab == "kernels":
            us = r.get("xla_flash_us") or r.get("xla_ref_us") or ""
            print(f"kernels/{r['name']},{us},"
                  f"err={r['pallas_vs_ref_err']:.2e}")
        elif tab == "roofline" and "dominant" in r:
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},,"
                  f"dom={r['dominant']},frac={r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
