"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per benchmark plus the full
JSON rows to runs/bench_results.json.  Benchmarks with per-tenant runtime
accounting also emit schema-versioned ``runs/*_timeline.json`` artifacts
(core/obs.py, docs/observability.md) next to the bench JSON.

Sections:
  fig1      — technique-removal latency/throughput (paper Fig. 1)
  fig3/fig4 — CoRD overhead matrix & relative throughput (Figs. 3-4)
  window    — CQ-runtime bandwidth vs. sender-window depth (RC + UD)
  credits   — credit flow-control ablation (stall counters)
  serve     — gang vs continuous-slot serving (tok/s, TTFT, compiles)
  converged — train job + serve tenants on ONE dataplane under QoS
              arbitration (the converged-cloud scenario)
  fig5      — system-A preset (Fig. 5)
  fig6      — NPB suite bypass/cord/socket (Fig. 6)
  kernels   — Pallas kernel correctness + XLA timings
  roofline  — dry-run roofline terms (if runs/dryrun is populated)

Requires >=8 CPU devices: the driver re-execs itself with the XLA flag if
needed, so ``PYTHONPATH=src python -m benchmarks.run [--fast]`` suffices.
"""

from __future__ import annotations

import json
import os
import sys

from benchmarks._bootstrap import ensure_host_devices

ensure_host_devices(8, module="benchmarks.run")


def accumulate_report(totals: dict, report: dict) -> dict:
    """Fold one per-tenant counter report into host-side cumulative
    totals — additive columns sum, the ``cq_depth`` high-water mark takes
    the max.  Used wherever the dry-run smokes rebuild a cumulative
    timeline from repeated fresh-state transfers."""
    for tenant, ctrs in report.items():
        acc = totals.setdefault(tenant, dict.fromkeys(ctrs, 0.0))
        for k, v in ctrs.items():
            acc[k] = max(acc[k], v) if k == "cq_depth" else acc[k] + v
    return totals


def dry_run() -> None:
    """CI smoke: build the measured paths and execute a minimal slice of
    each — perftest ping-pong over the verbs layer, one NPB kernel in
    bypass+cord, and a per-tenant counter timeline over repeated windowed
    transfers, asserting the emitted artifact is well-formed — without
    the full figure sweeps."""
    import jax
    import jax.numpy as jnp

    from benchmarks import npb, perftest
    from repro.core.obs import CounterTimeline

    mesh2 = perftest.make_mesh2()
    dp = perftest._dp("cord", emulate=True, mesh=mesh2)
    lat = perftest.pingpong_latency_us(mesh2, dp, dp, 1024, iters=4)
    print(json.dumps({"table": "dryrun", "pingpong_us": round(lat, 2),
                      "pipeline": list(dp.pipeline.stage_names)}))
    gbps, rate, stats = perftest.windowed_throughput(
        mesh2, dp, dp, 1024, window=4, n_msgs=8)
    print(json.dumps({"table": "dryrun", "windowed_gbps": round(gbps, 3),
                      **stats}))

    # timeline smoke: several windowed transfers, each from a fresh
    # runtime state (build_windowed's body already allreduce_state-sums
    # its state over the mesh — feeding that aggregate back in would
    # re-psum it every call), with host-side accumulation into cumulative
    # per-tenant totals between calls; assert the saved artifact
    # round-trips as schema-valid with an honest, constant-work rate
    # series per tenant
    fn, _ = perftest.build_windowed(mesh2, dp, dp, 1024, n_msgs=8, window=4)
    msgs = jnp.zeros((2, 8, 1024), jnp.uint8)
    rt0 = dp.runtime_init()
    totals: dict[str, dict[str, float]] = {}
    timeline = CounterTimeline(source="bench-dryrun")
    for i in range(1, 5):
        _, _, rt = jax.block_until_ready(fn(msgs, rt0))
        accumulate_report(totals, dp.runtime_report(rt))
        timeline.snapshot(i, {t: dict(a) for t, a in totals.items()})
    path = timeline.save("runs/dryrun_timeline.json")
    doc = CounterTimeline.load(path)             # schema validation
    rates = doc["rates"][dp.tenant]
    assert len(rates["ops_s"]) == 3 and all(rates["ops_s"]), rates
    # identical transfers must account identical work per window — a
    # doubling series here means state got re-aggregated somewhere
    ops = [s["tenants"][dp.tenant]["ops"] for s in doc["samples"]]
    deltas = [b - a for a, b in zip(ops, ops[1:])]
    assert deltas and all(d == deltas[0] for d in deltas), ops
    print(json.dumps({"table": "dryrun", "timeline": path,
                      "samples": len(doc["samples"]),
                      "ops_s_last": round(rates["ops_s"][-1], 1)}))

    elastic_smoke()
    control_plane_smoke()
    bounce_smoke()
    transport_smoke()

    # converged train+serve contention smoke (benchmarks/converged.py):
    # serve tenants must keep nonzero tok/s while the QoS-throttled train
    # job runs on the same dataplane
    from benchmarks import converged
    converged.dry_run()

    for row in npb.run_all(benches=("EP",), modes=("bypass", "cord")):
        print(json.dumps(row))
    print("dry-run ok")


def elastic_smoke() -> None:
    """PR-5 acceptance smoke (docs/elasticity.md): a sustained ``denied``
    rate trips the ThresholdWatcher exactly once (hysteresis + cooldown
    hold), a windowed transfer in flight at trigger time survives a live
    QP migration onto a *different* 2-rank mesh bit-identically, and the
    saved v2 timeline artifact validates with the remesh event
    recorded."""
    import json

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import perftest
    from repro.configs.base import DataplaneConfig
    from repro.core import compat, verbs
    from repro.core.dataplane import Dataplane
    from repro.core.obs import CounterTimeline, ThresholdWatcher
    from repro.core.policies import QuotaPolicy, TelemetryPolicy

    n_msgs, msg_bytes, window = 8, 1024, 4
    mesh_a = perftest.make_mesh2()
    mesh_b = compat.make_mesh((2,), ("rank",), devices=jax.devices()[2:4])

    def dp_on(mesh):
        # observe-only quota: each round's runtime bytes blow a 2 KiB
        # budget, so the denied counter climbs every round — the
        # sustained trigger signal
        return Dataplane(
            DataplaneConfig(mode="cord", emulate_costs=True), mesh=mesh,
            policies=[TelemetryPolicy(),
                      QuotaPolicy(hard=False, limits={"default": 2048})])

    dp_a, dp_b = dp_on(mesh_a), dp_on(mesh_b)
    payload = np.arange(n_msgs * msg_bytes, dtype=np.uint8) \
        .reshape(n_msgs, msg_bytes)
    msgs = jnp.asarray(np.stack([payload, np.zeros_like(payload)]))
    conn_a = perftest.build_migratable(mesh_a, dp_a, msg_bytes, window,
                                       credits=n_msgs)
    conn_b = perftest.build_migratable(mesh_b, dp_b, msg_bytes, window)

    # --- watched run: repeated transfers, denied% sustained over the
    # threshold in EVERY window; hysteresis must fire exactly once ------
    timeline = CounterTimeline(source="bench-elastic")
    watcher = ThresholdWatcher({"denied_pct": 40.0}, sustain=2, cooldown=16)
    totals: dict[str, dict[str, float]] = {}
    for i in range(1, 7):
        qp, _ = conn_a["init"](dp_a.runtime_init())
        _, _, rt = jax.block_until_ready(
            conn_a["xfer"](msgs, qp, dp_a.runtime_init()))
        accumulate_report(totals, dp_a.runtime_report(rt))
        timeline.snapshot(i, {t: dict(a) for t, a in totals.items()},
                          gauges=watcher.gauges())
        for ev in watcher.observe(timeline):
            timeline.record_event(ev["kind"], ev["step"],
                                  tenant=ev["tenant"], t=ev["t"],
                                  detail=ev["detail"])
    assert len(watcher.triggers) == 1, \
        f"hysteresis broke: {len(watcher.triggers)} triggers, expected 1"
    trigger_step = watcher.triggers[0]["step"]
    assert trigger_step == 1 + watcher.sustain, watcher.triggers

    # --- the response: live QP migration of an in-flight transfer ------
    # baseline: one uninterrupted transfer on mesh A
    qp, _ = conn_a["init"](dp_a.runtime_init())
    full_out, qp_full, _ = jax.block_until_ready(
        conn_a["xfer"](msgs, qp, dp_a.runtime_init()))
    # migrated: half on mesh A, quiesce → stop-and-copy → restore on
    # mesh B, the rest there — outstanding credits ride along
    k = n_msgs // 2
    qp, _ = conn_a["init"](dp_a.runtime_init())
    out1, qp, _ = conn_a["xfer"](msgs[:, :k], qp, dp_a.runtime_init())
    qp, _ = conn_a["quiesce"](qp, dp_a.runtime_init())
    snap = verbs.qp_snapshot(qp)
    assert int(snap["cq_head"] - snap["cq_tail"]) == 0, "CQ not quiesced"
    assert int(snap["credits"]) == n_msgs - k, "credits lost in migration"
    qp_b = verbs.qp_restore(snap, mesh_b)
    out2, qp_b, _ = jax.block_until_ready(
        conn_b["xfer"](msgs[:, k:], qp_b, dp_b.runtime_init()))
    moved = np.concatenate([np.asarray(out1)[1], np.asarray(out2)[1]])
    np.testing.assert_array_equal(moved, np.asarray(full_out)[1])
    snap_b, snap_f = verbs.qp_snapshot(qp_b), verbs.qp_snapshot(qp_full)
    for key in ("sq_head", "cq_sent", "credits", "rx_owed"):
        assert int(snap_b[key]) == int(snap_f[key]), \
            f"{key} diverged across the migration"
    timeline.record_event(
        "remesh", trigger_step, tenant="default",
        detail={"from": "mesh_a", "to": "mesh_b", "migrated_msgs": k})

    # --- the artifact records the whole loop ---------------------------
    path = timeline.save("runs/elastic_timeline.json")
    doc = CounterTimeline.load(path)              # schema validation (v2)
    kinds = [e["kind"] for e in doc["events"]]
    assert kinds.count("trigger") == 1 and kinds.count("remesh") == 1, kinds
    print(json.dumps({"table": "dryrun", "elastic_timeline": path,
                      "trigger_step": trigger_step,
                      "migrated_bit_identical": True,
                      "events": kinds}))


def control_plane_smoke() -> None:
    """Pod-scale control-plane smoke (docs/elasticity.md): two "hosts"
    — disjoint 2-device meshes, one carrying quota-metered train-side
    verbs traffic, the other a real serving engine with a rate-limited
    tenant — stream per-process timelines that merge step-aligned into
    ONE pod timeline each round.  A :class:`WatcherGroup` runs a
    train-remesh watcher and a serve-budget watcher over the merged
    rates:

    * the noisy phase trips BOTH.  The train response live-migrates an
      in-flight windowed QP transfer onto the spare mesh (shrink); the
      serve response halves the engine's per-tenant slot budget.
    * the quiet phase fires both release arms: the still-in-flight
      transfer migrates BACK onto its original mesh (grow) and the
      budget is restored — the closed shrink→recover→grow cycle.

    The migrated transfer must complete bit-identically to an
    uninterrupted one across BOTH migrations, and the saved merged pod
    artifact must validate with the full trigger→remesh(shrink)→
    recover→remesh(grow) sequence plus both budget moves recorded."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import perftest
    from repro.configs.base import (DataplaneConfig, ElasticConfig,
                                    ServeConfig)
    from repro.core import compat, verbs
    from repro.core.dataplane import Dataplane
    from repro.core.obs import (CounterTimeline, ThresholdWatcher,
                                WatcherGroup, merge_timelines)
    from repro.core.policies import QoSPolicy, QuotaPolicy, TelemetryPolicy
    from repro.runtime import ServeElasticController

    n_msgs, msg_bytes, window = 8, 1024, 4
    mesh_a = perftest.make_mesh2()
    mesh_b = compat.make_mesh((2,), ("rank",), devices=jax.devices()[2:4])
    # host 0: train-side traffic over an observe-only 2 KiB quota — every
    # noisy round blows the budget, so denied_pct sustains over threshold
    dp_a = Dataplane(
        DataplaneConfig(mode="cord", emulate_costs=True), mesh=mesh_a,
        policies=[TelemetryPolicy(),
                  QuotaPolicy(hard=False, limits={"default": 2048})])
    dp_b = Dataplane(DataplaneConfig(mode="cord", emulate_costs=True),
                     mesh=mesh_b, policies=[TelemetryPolicy()])
    conn_a = perftest.build_migratable(mesh_a, dp_a, msg_bytes, window,
                                       credits=n_msgs)
    conn_b = perftest.build_migratable(mesh_b, dp_b, msg_bytes, window)
    payload = np.arange(n_msgs * msg_bytes, dtype=np.uint8) \
        .reshape(n_msgs, msg_bytes)
    msgs = jnp.asarray(np.stack([payload, np.zeros_like(payload)]))

    # host 1: a real engine whose "burst" tenant is admission-limited, so
    # its deferrals (the throttled column) climb while requests queue
    from repro.configs import get_model_config
    from repro.models import build_model
    from repro.serve import Engine, Request

    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dp_serve = Dataplane(
        DataplaneConfig(mode="cord", emulate_costs=True),
        mesh=compat.make_mesh((8,), ("data",)), tenant="steady",
        tenants=("steady", "burst"),
        policies=[TelemetryPolicy(),
                  QoSPolicy(rates={"burst": 0.1}, burst=1.0)])
    eng = Engine(model, params, cfg,
                 ServeConfig(max_batch=2, max_new_tokens=4,
                             kv_cache_len=64),
                 dp=dp_serve, eos_id=-1)

    tl_a = CounterTimeline(source="host0")   # controller host: events land here
    tl_b = CounterTimeline(source="host1")
    group = WatcherGroup({
        "train": ThresholdWatcher({"denied_pct": 40.0}, sustain=2,
                                  cooldown=1, tenants=("default",),
                                  release={"denied_pct": 5.0},
                                  release_sustain=2, release_cooldown=8),
        "serve": ThresholdWatcher({"throttled_pct": 10.0}, sustain=2,
                                  cooldown=1, tenants=("burst",),
                                  release={"throttled_pct": 1.0},
                                  release_sustain=2, release_cooldown=8),
    })
    serve_ctl = ServeElasticController(
        ElasticConfig(enabled=True, shrink_factor=2), tl_a, eng)

    # the in-flight migratable transfer and its uninterrupted baseline
    qp_full, _ = conn_a["init"](dp_a.runtime_init())
    full_out, qp_full, _ = jax.block_until_ready(
        conn_a["xfer"](msgs, qp_full, dp_a.runtime_init()))
    k1, k2 = 3, 6                       # migration points: A | B | A again
    parts: list[np.ndarray] = []
    qp_live = None                      # in-flight QP, wherever it lives

    def wave(i):
        return [Request(rid=10 * i + j,
                        prompt=np.asarray((np.arange(8) + i + j) % 97,
                                          np.int32),
                        max_new_tokens=4,
                        tenant="burst" if j == 2 else "steady")
                for j in range(3)]

    totals: dict[str, dict[str, float]] = {}
    seen: list[str] = []                # the pod-level event storyline
    for i in range(1, 7):
        noisy = i <= 3
        if noisy:
            # host 0 under pressure: a fresh quota-blowing transfer
            qp, _ = conn_a["init"](dp_a.runtime_init())
            _, _, rt = jax.block_until_ready(
                conn_a["xfer"](msgs, qp, dp_a.runtime_init()))
            accumulate_report(totals, dp_a.runtime_report(rt))
            eng.run(wave(i))            # host 1 under pressure too
        else:
            # post-shrink quiet: host 0's tenant now runs clean on the
            # spare mesh (no quota there), host 1 goes idle
            if qp_live is not None and len(parts) == 1:
                out, qp_live, rt = jax.block_until_ready(conn_b["xfer"](
                    msgs[:, k1:k2], qp_live, dp_b.runtime_init()))
                parts.append(np.asarray(out)[1])
            else:
                qp, _ = conn_b["init"](dp_b.runtime_init())
                _, _, rt = jax.block_until_ready(
                    conn_b["xfer"](msgs, qp, dp_b.runtime_init()))
            accumulate_report(totals, dp_b.runtime_report(rt))
        tl_a.snapshot(i, {t: dict(a) for t, a in totals.items()},
                      gauges=group.gauges(), t=float(i))
        tl_b.snapshot_block(i, *eng.runtime_counters(), t=float(i))

        pod = merge_timelines([tl_a, tl_b], source="pod")
        evs = group.observe(pod, record=False)
        for ev in evs["train"] + evs["serve"]:
            tl_a.record_event(ev["kind"], ev["step"], tenant=ev["tenant"],
                              t=ev["t"], detail=ev["detail"])
            seen.append(f"{ev['detail']['watcher']}:{ev['kind']}")
        for ev in evs["train"]:
            if ev["kind"] == "trigger":
                # shrink response: migrate the in-flight transfer A → B
                qp_live, _ = conn_a["init"](dp_a.runtime_init())
                out, qp_live, _ = conn_a["xfer"](msgs[:, :k1], qp_live,
                                                 dp_a.runtime_init())
                parts.append(np.asarray(out)[1])
                qp_live, _ = conn_a["quiesce"](qp_live, dp_a.runtime_init())
                snap = verbs.qp_snapshot(qp_live)
                assert int(snap["credits"]) == n_msgs - k1, snap["credits"]
                qp_live = verbs.qp_restore(snap, mesh_b)
                tl_a.record_event("remesh", i, tenant="default",
                                  t=float(i) + 0.5,
                                  detail={"watcher": "train",
                                          "direction": "shrink",
                                          "from": "mesh_a", "to": "mesh_b",
                                          "migrated_msgs": k1})
                seen.append("train:remesh-shrink")
            elif ev["kind"] == "recover":
                # grow-back: migrate the STILL-in-flight transfer B → A
                qp_live, _ = conn_b["quiesce"](qp_live, dp_b.runtime_init())
                snap = verbs.qp_snapshot(qp_live)
                assert int(snap["credits"]) == n_msgs - k2, snap["credits"]
                qp_live = verbs.qp_restore(snap, mesh_a)
                out, qp_live, _ = jax.block_until_ready(conn_a["xfer"](
                    msgs[:, k2:], qp_live, dp_a.runtime_init()))
                parts.append(np.asarray(out)[1])
                tl_a.record_event("remesh", i, tenant="default",
                                  t=float(i) + 0.5,
                                  detail={"watcher": "train",
                                          "direction": "grow",
                                          "from": "mesh_b", "to": "mesh_a",
                                          "migrated_msgs": n_msgs - k2})
                seen.append("train:remesh-grow")
        serve_ctl.respond(evs["serve"])

    # the storyline closed in order, once each
    assert seen == ["train:trigger", "serve:trigger", "train:remesh-shrink",
                    "train:recover", "serve:recover", "train:remesh-grow"] \
        or seen == ["train:trigger", "serve:trigger", "train:remesh-shrink",
                    "serve:recover", "train:recover", "train:remesh-grow"], \
        seen
    assert serve_ctl.shrinks == 1 and serve_ctl.grows == 1
    assert eng.slot_budget() == 2, eng.slot_budget()   # restored

    # bit-identical across BOTH migrations
    moved = np.concatenate(parts)
    np.testing.assert_array_equal(moved, np.asarray(full_out)[1])
    snap_l, snap_f = verbs.qp_snapshot(qp_live), verbs.qp_snapshot(qp_full)
    for key in ("sq_head", "cq_sent", "credits", "rx_owed"):
        assert int(snap_l[key]) == int(snap_f[key]), \
            f"{key} diverged across shrink+grow migration"

    # the merged pod artifact records the whole cycle
    pod = merge_timelines([tl_a, tl_b], source="pod")
    path = pod.save("runs/control_plane_timeline.json")
    doc = CounterTimeline.load(path)             # schema validation (v2)
    kinds = [e["kind"] for e in doc["events"]]
    dirs = [e["detail"]["direction"] for e in doc["events"]
            if e["kind"] == "remesh"]
    assert dirs == ["shrink", "grow"], dirs
    budget_dirs = [e["detail"]["direction"] for e in doc["events"]
                   if e["kind"] == "budget"]
    assert budget_dirs == ["shrink", "grow"], budget_dirs
    assert kinds.count("trigger") == 2 and kinds.count("recover") == 2
    # merged counters really are the pod sum: host tenants are disjoint
    # here, so every part tenant must appear in the merged doc
    assert {"default", "steady", "burst"} <= set(doc["tenants"])
    print(json.dumps({"table": "dryrun", "control_plane_timeline": path,
                      "storyline": seen,
                      "slot_budget": eng.slot_budget(),
                      "migrated_bit_identical": True}))


def transport_smoke() -> None:
    """PR-7 acceptance smoke (docs/transport.md): injected wire loss is
    *non-terminal* — a windowed transfer through the go-back-N
    retransmission machine delivers bit-identically to its lossless twin,
    the retries/timeouts land in the tenant counters and the timeline's
    ``retrans_s``/``timeouts_s`` rate series, and a connection-churn
    round live-migrates a lossy shared-CQ table bit-identically."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import perftest
    from repro.core.obs import CounterTimeline
    from repro.runtime.fault import WireFault

    n_msgs, msg_bytes, window = 8, 1024, 4
    mesh2 = perftest.make_mesh2()
    dp = perftest._dp("cord", emulate=True, mesh=mesh2)
    payload = np.arange(n_msgs * msg_bytes, dtype=np.uint8) \
        .reshape(n_msgs, msg_bytes)
    msgs = jnp.asarray(np.stack([payload, np.zeros_like(payload)]))
    fault = WireFault(drop_rate=0.2, corrupt_rate=0.1, seed=5)

    clean, _ = perftest.build_windowed(mesh2, dp, dp, msg_bytes, n_msgs,
                                       window)
    lossy, _ = perftest.build_windowed(mesh2, dp, dp, msg_bytes, n_msgs,
                                       window, fault=fault)
    out0, _, _ = jax.block_until_ready(clean(msgs, dp.runtime_init()))
    out1, _, rt = jax.block_until_ready(lossy(msgs, dp.runtime_init()))
    np.testing.assert_array_equal(
        np.asarray(out1)[1], np.asarray(out0)[1],
        err_msg="lossy windowed transfer is not bit-identical to lossless")
    np.testing.assert_array_equal(np.asarray(out1)[1], payload)
    rep = dp.runtime_report(rt)[dp.tenant]
    assert rep["retransmits"] > 0, rep
    assert rep["retransmits"] + rep["timeouts"] + rep["cqe_errors"] > 0

    # the fault series is a first-class timeline rate
    timeline = CounterTimeline(source="transport-smoke")
    timeline.snapshot(0, dp.runtime_report(dp.runtime_init()))
    timeline.snapshot(1, dp.runtime_report(rt))
    rates = timeline.rates()[dp.tenant]
    assert rates["retrans_s"][-1] > 0, rates
    path = timeline.save("runs/transport_timeline.json")
    CounterTimeline.load(path)                    # schema validation

    # mini churn: lossy tables created → migrated mid-transfer → torn
    # down (the ≥100-QP sweep is perftest --dry-run's churn_dryrun table)
    (row,) = perftest.connection_churn(mesh2, rounds=2, qps=8,
                                       msg_bytes=64, emulate=False,
                                       table="churn_smoke")
    assert row["bit_identical"] and row["qps_churned"] == 16, row
    print(json.dumps({"table": "dryrun",
                      "lossy_vs_lossless": "bit-identical",
                      "retransmits": rep["retransmits"],
                      "timeouts": rep["timeouts"],
                      "cqe_errors": rep["cqe_errors"],
                      "retrans_s_last": round(rates["retrans_s"][-1], 2),
                      "transport_timeline": path}))
    print(json.dumps(row))


def bounce_smoke() -> None:
    """PR-6 acceptance smoke (docs/kernels.md): the Pallas dataplane
    kernels are bit-identical to the XLA emulation they replace — the
    double-buffered ``bounce_copy`` against ``staged_copy`` on a ragged
    payload (exercising the padded-tail DMA path), and ``mediated_cost``
    must leave the payload untouched while its per-chunk SMEM counters
    account at least the requested delay iterations."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.techniques import staged_copy
    from repro.kernels.dataplane import (COST_COPIES, COST_ITERS,
                                         bounce_copy, mediated_cost)

    x = jax.random.normal(jax.random.PRNGKey(6), (3, 1237), jnp.float32)
    for copies in (1, 3):
        np.testing.assert_array_equal(
            np.asarray(bounce_copy(x, copies=copies, chunk_elems=1024)),
            np.asarray(staged_copy(x, copies=copies)))
    out, ctrs = mediated_cost(x, delay_iters=500, copies=2,
                              chunk_elems=1024)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    ctrs = np.asarray(ctrs)
    assert int(ctrs[:, COST_ITERS].sum()) >= 500, ctrs
    assert (ctrs[:, COST_COPIES] == 2).all(), ctrs
    print(json.dumps({"table": "dryrun", "bounce_bit_identical": True,
                      "cost_chunks": int(ctrs.shape[0]),
                      "cost_iters": int(ctrs[:, COST_ITERS].sum())}))


def main() -> None:
    if "--transport-smoke" in sys.argv:
        # the PR-7 acceptance gate, runnable standalone (ci.yml step):
        # wire loss must be non-terminal and bit-identical on delivery
        transport_smoke()
        print("transport smoke ok")
        return
    if "--control-plane-smoke" in sys.argv:
        # the PR-10 acceptance gate, runnable standalone (the ci.yml
        # control-plane lane): the multi-process-mesh shrink→recover→grow
        # cycle must close with bit-identical transfers and a validated
        # merged pod artifact
        control_plane_smoke()
        print("control-plane smoke ok")
        return
    if "--dry-run" in sys.argv:
        dry_run()
        return
    fast = "--fast" in sys.argv
    rows = []

    print("# perftest (figs 1, 3, 4, 5)")
    from benchmarks import perftest
    rows += perftest.run_all(fast=fast)

    print("# NPB (fig 6)")
    from benchmarks import npb
    rows += npb.run_all()

    print("# serve (gang vs continuous slots)")
    from benchmarks import serve
    rows += serve.run_all(fast=fast)

    print("# converged (train + serve on one dataplane)")
    from benchmarks import converged
    rows += converged.run_all(fast=fast)

    print("# kernels")
    from benchmarks import kernels_bench
    rows += kernels_bench.run_all()

    if os.path.isdir("runs/dryrun") and os.listdir("runs/dryrun"):
        print("# roofline (from dry-run artifacts)")
        from benchmarks import roofline
        roof = roofline.run_all(use_hlo=not fast)
        rows += [{"table": "roofline", **r} for r in roof]

    os.makedirs("runs", exist_ok=True)
    with open("runs/bench_results.json", "w") as f:
        json.dump(rows, f, indent=1, default=str)

    # CSV summary: name,us_per_call,derived
    print("name,us_per_call,derived")
    for r in rows:
        tab = r.get("table", "?")
        if tab == "fig1":
            print(f"fig1/{r['variant']}/{r['bytes']}B,{r['latency_us']},"
                  f"gbps={r['gbps']}")
        elif tab in ("fig3", "fig5_lat"):
            print(f"{tab}/{r['transport']}/{r['op']}/{r['client']}-"
                  f"{r['server']},{r['latency_us']},"
                  f"overhead_us={r['overhead_us']}")
        elif tab in ("fig4", "fig5_bw"):
            print(f"{tab}/{r['transport']}/{r['op']}/{r['bytes']}B,,"
                  f"rel_tput={r['rel_throughput']}")
        elif tab == "window":
            print(f"window/{r['transport']}/{r['op']}/{r['bytes']}B/"
                  f"w{r['window']},,gbps={r['gbps']} cq={r['cq_hwm']}")
        elif tab == "credits":
            print(f"credits/{r['bytes']}B/w{r['window']}/"
                  f"c{r['rx_credits']},,gbps={r['gbps']} "
                  f"stalls={r['stalls']}")
        elif tab == "churn":
            print(f"churn/{r['qps_churned']}qp/"
                  f"drop{r['drop_rate']},,retrans={r['retransmits']} "
                  f"timeouts={r['timeouts']} "
                  f"bit_identical={r['bit_identical']}")
        elif tab == "serve":
            print(f"serve/{r['scheduler']}/q{r['queue_depth']},,"
                  f"tok_s={r['tok_s']} ttft_ms={r['ttft_ms_mean']} "
                  f"compiles={r['decode_compiles']}")
        elif tab == "converged":
            served = sum(r["served_tokens"].values())
            print(f"converged/throttle={r['throttle_train']},,"
                  f"train_wall_s={r['train_wall_s']} "
                  f"served_tokens={served} "
                  f"train_throttled={r['train_throttled']}")
        elif tab == "fig6":
            print(f"fig6/{r['bench']}/{r['mode']},{r['ms'] * 1e3},"
                  f"rel={r['rel_runtime']}")
        elif tab == "kernels":
            us = r.get("xla_flash_us") or r.get("xla_ref_us") or ""
            print(f"kernels/{r['name']},{us},"
                  f"err={r['pallas_vs_ref_err']:.2e}")
        elif tab == "roofline" and "dominant" in r:
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},,"
                  f"dom={r['dominant']},frac={r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
