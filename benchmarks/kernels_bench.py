"""Kernel microbenchmarks: Pallas (interpret) vs XLA-flash vs naive
reference — correctness deltas + us/call for the XLA paths (the Pallas
interpret numbers are correctness artifacts, not perf — noted)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _t(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_flash():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    from repro.layers.attention import attend
    rows = []
    B, H, KVH, D = 2, 8, 4, 64
    for S in (256, 1024):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KVH, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KVH, D), jnp.float32)
        pos = jnp.arange(S)
        ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                                  k.transpose(0, 2, 1, 3),
                                  v.transpose(0, 2, 1, 3))
        pallas_o = flash_attention(q, k, v, causal=True).transpose(0, 2, 1, 3)
        err = float(jnp.abs(pallas_o - ref).max())
        xla = jax.jit(lambda q, k, v: attend(
            q, k, v, q_pos=pos, k_pos=pos, causal=True, window=None,
            impl="flash"))
        naive = jax.jit(lambda q, k, v: attend(
            q, k, v, q_pos=pos, k_pos=pos, causal=True, window=None,
            impl="naive"))
        rows.append({"table": "kernels", "name": f"flash_attn_S{S}",
                     "pallas_vs_ref_err": err,
                     "xla_flash_us": round(_t(xla, q, k, v), 1),
                     "naive_us": round(_t(naive, q, k, v), 1)})
    return rows


def bench_ssm():
    from repro.kernels.ssm_scan.ops import ssm_scan
    from repro.kernels.ssm_scan.ref import ssm_scan_ref
    rows = []
    B, DI, N = 2, 256, 16
    for S in (256, 1024):
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, DI)))
        x = jax.random.normal(ks[1], (B, S, DI))
        a = -jnp.exp(jax.random.normal(ks[2], (DI, N)) * 0.3)
        b = jax.random.normal(ks[3], (B, S, N))
        c = jax.random.normal(ks[4], (B, S, N))
        y1, h1 = ssm_scan(dt, x, a, b, c)
        y2, h2 = ssm_scan_ref(dt, x, a, b, c,
                              jnp.zeros((B, DI, N), jnp.float32))
        err = float(jnp.abs(y1 - y2).max())
        ref = jax.jit(lambda *t: ssm_scan_ref(
            *t, jnp.zeros((B, DI, N), jnp.float32))[0])
        rows.append({"table": "kernels", "name": f"ssm_scan_S{S}",
                     "pallas_vs_ref_err": err,
                     "xla_ref_us": round(_t(ref, dt, x, a, b, c), 1)})
    return rows


def bench_bounce():
    """Dataplane bounce-buffer sweep: Pallas double-buffered copy kernel
    vs the XLA ``staged_copy`` emulation, across payload sizes and copy
    counts.  On CPU the Pallas path runs in interpret mode, so its time
    is a correctness artifact; the XLA bandwidth is compared against the
    HBM roofline to show how much headroom the emulation leaves (the
    motivation for the real kernel on TPU)."""
    import numpy as np

    from benchmarks.roofline import HBM_BW
    from repro.core.techniques import staged_copy
    from repro.kernels.dataplane import bounce_copy

    rows = []
    for elems in (1 << 14, 1 << 17):
        for copies in (1, 2):
            x = jax.random.normal(jax.random.PRNGKey(2), (elems,),
                                  jnp.float32)
            xla = jax.jit(lambda v: staged_copy(v, copies=copies))
            pal = jax.jit(lambda v: bounce_copy(v, copies=copies))
            err = float(np.abs(np.asarray(pal(x)) -
                               np.asarray(xla(x))).max())
            xla_us = _t(xla, x)
            # each copy moves the payload in and out of the bounce buffer
            moved = 2 * copies * x.size * x.dtype.itemsize
            gbps = moved / (xla_us * 1e-6) / 1e9
            rows.append({"table": "kernels",
                         "name": f"bounce_{elems * 4 // 1024}KiB_c{copies}",
                         "pallas_vs_ref_err": err,
                         "xla_ref_us": round(xla_us, 1),
                         "pallas_interpret_us": round(_t(pal, x), 1),
                         "xla_gbps": round(gbps, 2),
                         "hbm_roofline_frac": round(gbps * 1e9 / HBM_BW, 4)})
    return rows


def run_all():
    return bench_flash() + bench_ssm() + bench_bounce()


if __name__ == "__main__":
    import json
    for r in run_all():
        print(json.dumps(r))
