"""Shared host-device bootstrap for benchmark entry points.

The perftest/NPB harnesses need several XLA host-platform devices;
``ensure_host_devices`` re-execs the entry point with ``XLA_FLAGS`` set
(or raised) when the current environment requests fewer than needed.
Keep this module import-light: it must run before jax is imported.
"""

from __future__ import annotations

import os
import re
import sys

_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_devices(n: int, module: str) -> None:
    """Re-exec ``python -m <module> <argv>`` with at least ``n`` XLA host
    devices configured.  No-op when XLA_FLAGS already requests >= n."""
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(re.escape(_FLAG) + r"=(\d+)", flags)
    if m and int(m.group(1)) >= n:
        return
    if m:
        flags = flags.replace(m.group(0), f"{_FLAG}={n}")
    else:
        flags = f"{flags} {_FLAG}={n}"
    os.environ["XLA_FLAGS"] = flags
    os.execv(sys.executable, [sys.executable, "-m", module] + sys.argv[1:])


__all__ = ["ensure_host_devices"]
