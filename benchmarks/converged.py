"""Converged train+serve benchmark: one dataplane, contending tenants.

    PYTHONPATH=src python -m benchmarks.converged [--fast] [--dry-run]

The converged-cloud scenario the paper argues for: a data-parallel train
job and latency-sensitive serve tenants share ONE dataplane, with the
kernel-owned control plane (QoS classes + per-tenant token buckets)
arbitrating between them instead of static partitioning.  Each round
interleaves one explicit-DP train step (gradient all-reduce issued
through the dataplane, runtime accounting on) with a wave of serve
requests from two tenants on a continuous-batching engine.

The run emits one schema-versioned timeline artifact
(``runs/converged_timeline.json``): per-tick serve snapshots from the
engine plus a ``train_step`` control-plane event per round carrying the
loss and the train tenant's cumulative throttle count.

``--dry-run`` is the CI smoke: with the train tenant rate-limited by a
:class:`~repro.core.policies.QoSPolicy` token bucket, every round must
(a) complete its train step with a finite loss, (b) serve a nonzero
token count to EACH serve tenant — the converged acceptance: serving
never starves while training runs — and (c) account train throttling in
the shared runtime state; the final artifact must validate round-trip.
"""

from __future__ import annotations

import json
import sys
import time

from benchmarks._bootstrap import ensure_host_devices

ensure_host_devices(8, module="benchmarks.converged")

ARCH = "gemma3-1b"
TENANTS = ("train", "alice", "bob")
ROUNDS = 6
WAVE = 4                       # serve requests per round (2 per tenant)
MAX_NEW = 4
GLOBAL_BATCH = 16
SEQ_LEN = 32


def _build():
    import jax

    from repro.configs import get_model_config
    from repro.models import build_model

    cfg = get_model_config(ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _dataplane(throttle_train: bool):
    """One shared dataplane: the train job is tenant ``train``; serve
    traffic rides tenants ``alice``/``bob``.  ``throttle_train`` arms the
    QoS token bucket on the train tenant (the arbitration under test);
    off, the same topology runs unarbitrated for the A/B row."""
    from repro.configs.base import DataplaneConfig
    from repro.core import compat
    from repro.core.dataplane import Dataplane
    from repro.core.policies import QoSPolicy, TelemetryPolicy

    mesh = compat.make_mesh((8,), ("data",))
    policies = [TelemetryPolicy()]
    if throttle_train:
        policies.append(QoSPolicy(rates={"train": 0.25}, burst=2.0,
                                  stall_ns=200.0))
    return Dataplane(DataplaneConfig(mode="cord", emulate_costs=True),
                     mesh=mesh, tenant="train", tenants=TENANTS,
                     policies=policies)


def _train_setup(model, dp):
    from repro.configs.base import RunConfig, TrainConfig
    from repro.data import DataConfig, SyntheticLM
    from repro.train import init_state, make_explicit_dp_step

    import jax

    run = RunConfig(train=TrainConfig(steps=ROUNDS, learning_rate=5e-3,
                                      warmup_steps=2))
    step = make_explicit_dp_step(model, run, dp, axis="data",
                                 runtime_accounting=True)
    state = init_state(model, jax.random.PRNGKey(1))
    ds = SyntheticLM(DataConfig(vocab_size=model.cfg.vocab_size,
                                seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH))
    return step, state, ds


def _serve_engine(cfg, model, params, dp, timeline):
    from repro.configs.base import ServeConfig
    from repro.serve import Engine

    return Engine(model, params, cfg,
                  ServeConfig(max_batch=2, max_new_tokens=MAX_NEW,
                              kv_cache_len=64),
                  dp=dp, eos_id=-1, obs=timeline)


def _wave(round_i: int):
    """One round's serve wave: WAVE requests alternating alice/bob."""
    import numpy as np

    from repro.serve import Request

    return [Request(rid=round_i * WAVE + i,
                    prompt=np.asarray((np.arange(8) + 3 * i + round_i) % 97,
                                      np.int32),
                    max_new_tokens=MAX_NEW,
                    tenant=TENANTS[1 + i % 2])
            for i in range(WAVE)]


def _served_tokens(eng) -> dict[str, int]:
    rep = eng.tenant_report()
    return {t: int(rep.get(t, {}).get("tokens", 0)) for t in TENANTS[1:]}


def converged_run(throttle_train: bool, rounds: int = ROUNDS,
                  timeline=None) -> dict:
    """Round-interleaved train+serve on one dataplane; returns the row."""
    import jax
    import jax.numpy as jnp

    cfg, model, params = _build()
    dp = _dataplane(throttle_train)
    step, state, ds = _train_setup(model, dp)
    eng = _serve_engine(cfg, model, params, dp, timeline)
    rt = dp.runtime_init()

    losses, per_round, train_wall = [], [], 0.0
    for i in range(rounds):
        before = _served_tokens(eng)
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        t0 = time.perf_counter()
        state, metrics, rt = jax.block_until_ready(step(state, batch, rt))
        train_wall += time.perf_counter() - t0
        losses.append(float(metrics["loss"]))

        t0 = time.perf_counter()
        done = eng.run(_wave(i))
        serve_wall = time.perf_counter() - t0
        after = _served_tokens(eng)
        delta = {t: after[t] - before[t] for t in after}
        per_round.append({"round": i, "loss": losses[-1],
                          "served": delta, "serve_wall_s": serve_wall,
                          "completed": len(done)})
        if timeline is not None:
            tick = timeline.samples[-1]["step"] if timeline.samples else i
            timeline.record_event(
                "train_step", tick, tenant="train",
                detail={"round": i, "loss": losses[-1],
                        "throttled": float(
                            dp.runtime_report(rt)["train"]["throttled"])})

    report = dp.runtime_report(rt)
    served = _served_tokens(eng)
    return {"table": "converged", "throttle_train": throttle_train,
            "rounds": rounds, "losses": [round(v, 4) for v in losses],
            "train_wall_s": round(train_wall, 3),
            "served_tokens": served,
            "train_throttled": float(report["train"]["throttled"]),
            "train_ops": float(report["train"]["ops"]),
            "rounds_detail": per_round}


def run_all(fast: bool = False) -> list[dict]:
    """A/B rows: the same converged workload with the train tenant's QoS
    bucket off and on — what arbitration costs the train job and buys the
    serve tenants."""
    rows = []
    rounds = 3 if fast else ROUNDS
    for throttle in (False, True):
        row = converged_run(throttle, rounds=rounds)
        rows.append(row)
        print(json.dumps({k: v for k, v in row.items()
                          if k != "rounds_detail"}))
    with open("BENCH_converged.json", "w") as f:
        json.dump({"bench": "converged", "rows": rows}, f, indent=1)
    print(json.dumps({"table": "converged",
                      "artifact": "BENCH_converged.json"}))
    return rows


def dry_run() -> None:
    """CI smoke for the converged dataplane (see module docstring)."""
    import math

    from repro.core.obs import CounterTimeline, validate_timeline

    timeline = CounterTimeline(source="bench-converged")
    row = converged_run(True, rounds=4, timeline=timeline)

    assert all(math.isfinite(v) for v in row["losses"]), row["losses"]
    for r in row["rounds_detail"]:
        assert r["completed"] == WAVE, r
        for tenant, toks in r["served"].items():
            assert toks > 0, \
                f"serve tenant {tenant} starved in round {r['round']}: {r}"
    assert row["train_throttled"] > 0, \
        "QoS bucket never throttled the train tenant — arbitration is idle"
    assert row["train_ops"] > 0

    path = timeline.save("runs/converged_timeline.json")
    doc = CounterTimeline.load(path)               # schema validation
    validate_timeline(doc)
    assert doc["samples"], "no serve ticks captured"
    events = [e for e in doc["events"] if e["kind"] == "train_step"]
    assert len(events) == 4, events
    assert all("loss" in e["detail"] for e in events)
    # serve traffic is visible in the shared artifact (tokens ride the
    # counter block's bytes column, Engine.runtime_counters)
    last = doc["samples"][-1]["tenants"]
    assert any(last.get(t, {}).get("bytes", 0) > 0 for t in TENANTS[1:])

    print(json.dumps({"table": "converged_dryrun", "timeline": path,
                      "ticks": len(doc["samples"]),
                      "losses": row["losses"],
                      "served_tokens": row["served_tokens"],
                      "train_throttled": row["train_throttled"]}))
    print("converged dry-run ok")


def main() -> None:
    if "--dry-run" in sys.argv:
        dry_run()
        return
    run_all(fast="--fast" in sys.argv)


if __name__ == "__main__":
    main()
