"""Serve-path benchmark: paged-KV vs fixed-stripe continuous batching,
with the legacy gang scheduler as the convoy baseline (sustained
tokens/s, p50/p99 time-to-first-token, decode-step compile counts).

    PYTHONPATH=src python -m benchmarks.serve [--fast] [--dry-run]

The sweep serves a mixed long+short prompt stream at queue depths well
past ``max_batch`` through three engines — gang, fixed-stripe continuous,
and paged continuous at *equal KV memory* (the paged engine trades the
stripe's per-slot headroom for extra decode slots) — and writes every
row into a ``BENCH_serve.json`` artifact next to the per-tick engine
timelines (``runs/serve_*_timeline.json``).

``--dry-run`` is the CI smoke: the paged engine must emit bit-identical
temperature-0 tokens to the fixed stripe on a uniform stream, admit (and
chunk-prefill) a prompt longer than any stripe, match-or-beat the
equal-memory stripe on tok/s with a lower p99 TTFT on the mixed stream,
and surface nonzero preemption/restore counters in the saved timeline
artifact.
"""

from __future__ import annotations

import json
import sys
import time

MAX_BATCH = 4
MAX_NEW = 32
KV_LEN = 56
BLOCK = 8
_VARIED_LENGTHS = (5, 9, 14, 7, 15, 6, 11, 13)   # buckets 8 / 16
# Per-request decode budgets: the wide spread is what exposes the gang
# convoy effect — every early finisher idles its slot until the gang's
# longest request (MAX_NEW steps) drains, while continuous refills it.
_VARIED_BUDGETS = (2, MAX_NEW, 3, 5)

# Equal-memory paged-vs-fixed pairing: the stripe engine preallocates
# FIXED_BATCH × PAIR_KV cache positions; the paged engine spends the same
# token capacity as a shared pool (PAIR_BLOCKS × BLOCK positions) and
# runs PAGED_BATCH slots over it — slot count decoupled from stripe size.
FIXED_BATCH = 2
PAGED_BATCH = 6
PAIR_KV = 128
PAIR_BLOCKS = FIXED_BATCH * PAIR_KV // BLOCK
_LONG_EVERY = 6                                   # 1 in 6 requests is long
_LONG_LEN, _LONG_NEW = 40, 24


def _build():
    import jax

    from repro.configs import get_model_config
    from repro.models import build_model

    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(n: int, equal_len: int = 0, mixed: bool = False):
    import numpy as np

    from repro.serve import Request

    reqs = []
    for i in range(n):
        if mixed and i % _LONG_EVERY == 0:
            ln, new = _LONG_LEN, _LONG_NEW
        else:
            ln = equal_len or _VARIED_LENGTHS[i % len(_VARIED_LENGTHS)]
            new = (MAX_NEW if equal_len else
                   _VARIED_BUDGETS[i % len(_VARIED_BUDGETS)])
        reqs.append(Request(
            rid=i, max_new_tokens=new,
            prompt=np.asarray((np.arange(ln) + 3 * i) % 100, np.int32)))
    return reqs


def _engine(cfg, model, params, scheduler: str, obs=None, *,
            max_batch: int = MAX_BATCH, kv_cache_len: int = KV_LEN,
            block_size: int = 0, n_blocks: int = 0, prefill_chunk: int = 512):
    from repro.configs.base import ServeConfig
    from repro.serve import Engine

    return Engine(model, params, cfg,
                  ServeConfig(max_batch=max_batch, max_new_tokens=MAX_NEW,
                              kv_cache_len=kv_cache_len, scheduler=scheduler,
                              block_size=block_size, n_blocks=n_blocks,
                              prefill_chunk=prefill_chunk),
                  eos_id=-1, obs=obs)


def _serve(eng, make_reqs, repeats: int = 1):
    """Serve ``make_reqs()`` ``repeats`` times on a warm engine, reporting
    the best wall clock (per-request streams are rebuilt each repeat so
    outputs don't accumulate).  TTFT percentiles come from the best
    repeat — queue wait included, which is exactly what the paged engine's
    extra slots (and chunked prefill) are supposed to shrink."""
    import numpy as np

    best, done, ttft = float("inf"), [], []
    for _ in range(repeats):
        reqs = make_reqs()
        t0 = time.perf_counter()
        out = eng.run(reqs)
        wall = time.perf_counter() - t0
        if wall < best:
            best, done = wall, out
            ttft = [r.t_first - t0 for r in out if r.t_first is not None]
    toks = sum(len(r.out_tokens) for r in done)
    return done, {
        "tok_s": round(toks / best, 1),
        "ttft_ms_p50": round(1e3 * float(np.percentile(ttft, 50)), 2)
        if ttft else 0.0,
        "ttft_ms_p99": round(1e3 * float(np.percentile(ttft, 99)), 2)
        if ttft else 0.0,
        "decode_compiles": eng.decode_compile_count(),
        "wall_s": round(best, 3),
    }


def _save_artifact(rows: list[dict], path: str = "BENCH_serve.json") -> str:
    with open(path, "w") as f:
        json.dump({"bench": "serve", "rows": rows}, f, indent=1)
    return path


_PAIR = {
    "gang": dict(max_batch=FIXED_BATCH, kv_cache_len=PAIR_KV),
    "fixed": dict(max_batch=FIXED_BATCH, kv_cache_len=PAIR_KV),
    "paged": dict(max_batch=PAGED_BATCH, kv_cache_len=PAIR_KV,
                  block_size=BLOCK, n_blocks=PAIR_BLOCKS),
}


def run_all(fast: bool = False) -> list[dict]:
    from repro.core.obs import CounterTimeline

    cfg, model, params = _build()
    depths = (8, 16) if fast else (8, 16, 32)      # queue depth ≫ max_batch
    rows = []
    for name, geom in _PAIR.items():
        scheduler = "gang" if name == "gang" else "continuous"
        # per-tick engine timeline, written next to the bench JSON
        timeline = CounterTimeline(source=f"bench-serve/{name}")
        eng = _engine(cfg, model, params, scheduler, obs=timeline, **geom)
        eng.run(_requests(8, mixed=True))          # warm the compile caches
        for n in depths:
            _, stats = _serve(eng, lambda n=n: _requests(n, mixed=True),
                              repeats=5)
            row = {"table": "serve", "engine": name,
                   "queue_depth": n, "max_new_tokens": MAX_NEW,
                   **geom, **stats}
            rows.append(row)
            print(json.dumps(row))
        path = timeline.save(f"runs/serve_{name}_timeline.json")
        print(json.dumps({"table": "serve", "engine": name,
                          "timeline": path,
                          "ticks": len(timeline.samples)}))
    print(json.dumps({"table": "serve",
                      "artifact": _save_artifact(rows)}))
    return rows


def dry_run() -> None:
    """CI smoke for the paged serving engine (see module docstring)."""
    from repro.core.obs import CounterTimeline
    from repro.serve import ServeError

    cfg, model, params = _build()
    rows = []

    # 1. uniform stream: gang ≡ fixed stripe ≡ paged at temperature 0,
    #    one decode compile on both continuous layouts
    done_g, _ = _serve(_engine(cfg, model, params, "gang"),
                       lambda: _requests(6, equal_len=8))
    fixed = _engine(cfg, model, params, "continuous")
    done_f, stats_f = _serve(fixed, lambda: _requests(6, equal_len=8))
    paged = _engine(cfg, model, params, "continuous", block_size=BLOCK)
    assert paged.paged, "paged layout did not activate"
    done_p, stats_p = _serve(paged, lambda: _requests(6, equal_len=8))
    out_g = {r.rid: r.out_tokens for r in done_g}
    out_f = {r.rid: r.out_tokens for r in done_f}
    out_p = {r.rid: r.out_tokens for r in done_p}
    assert out_f == out_g, "continuous != gang at temperature 0"
    assert out_p == out_f, "paged != fixed stripe at temperature 0"
    assert stats_f["decode_compiles"] == 1, stats_f
    assert stats_p["decode_compiles"] == 1, stats_p

    # 2. a prompt longer than ANY fixed stripe: the stripe engine rejects
    #    it at submit; the paged engine serves it (chunk-at-a-time
    #    prefill, 80 tokens through 16-token chunks), and chunked prefill
    #    changes no tokens vs whole-prompt paged prefill
    long_req = lambda: _requests(1, equal_len=80)
    try:
        fixed.run(long_req())
        raise AssertionError("stripe engine admitted an 80-token prompt "
                             f"into kv_cache_len={KV_LEN}")
    except ServeError:
        pass
    whole = _engine(cfg, model, params, "continuous", block_size=BLOCK)
    (done_w,) = whole.run(long_req())
    chunked = _engine(cfg, model, params, "continuous", block_size=BLOCK,
                      prefill_chunk=16)
    assert chunked.chunked, "chunked prefill did not activate"
    (done_c,) = chunked.run(long_req())
    assert len(done_w.out_tokens) == MAX_NEW
    assert done_c.out_tokens == done_w.out_tokens, \
        "chunked prefill != whole prefill at temperature 0"

    # 3. equal-memory mixed sweep: paged (more slots, same KV tokens)
    #    must match-or-beat the fixed stripe on sustained tok/s and p99
    #    TTFT at a queue depth well past either batch
    pair = {}
    for name in ("fixed", "paged"):
        scheduler = "continuous"
        eng = _engine(cfg, model, params, scheduler, **_PAIR[name])
        eng.run(_requests(8, mixed=True))          # warm compile caches
        _, stats = _serve(eng, lambda: _requests(18, mixed=True), repeats=3)
        pair[name] = stats
        rows.append({"table": "serve_dryrun", "engine": name,
                     "queue_depth": 18, **_PAIR[name], **stats})
    assert pair["paged"]["tok_s"] >= pair["fixed"]["tok_s"], pair
    assert pair["paged"]["ttft_ms_p99"] <= pair["fixed"]["ttft_ms_p99"], pair

    # 4. preemption visibility: a pool too small for both residents
    #    forces preempt→resume, and the counters land in the timeline
    #    artifact (cumulative counters + preempt_s/restore_s rates)
    timeline = CounterTimeline(source="bench-serve/dryrun")
    # 9 blocks fit one request's whole lifetime (the submit bound) but
    # not two co-residents' decode growth (5 blocks each by the end)
    tiny = _engine(cfg, model, params, "continuous", obs=timeline,
                   max_batch=2, kv_cache_len=64, block_size=BLOCK,
                   n_blocks=9)
    done_t = tiny.run(_requests(2, equal_len=8))
    assert all(len(r.out_tokens) == MAX_NEW for r in done_t)
    rep = tiny.tenant_report()["default"]
    assert rep["preemptions"] > 0 and rep["restores"] > 0, rep
    path = timeline.save("runs/serve_dryrun_timeline.json")
    doc = CounterTimeline.load(path)               # validates the schema
    assert doc["samples"], "engine timeline captured no ticks"
    last = doc["samples"][-1]["tenants"]["default"]
    assert last["preemptions"] > 0 and last["restores"] > 0, last
    assert "preempt_s" in doc["rate_fields"] and \
        "restore_s" in doc["rate_fields"], doc["rate_fields"]
    assert "free_blocks" in doc["samples"][-1]["gauges"]

    print(json.dumps({"table": "serve_dryrun", "requests": len(out_p),
                      "timeline": path, "ticks": len(doc["samples"]),
                      "preemptions": rep["preemptions"],
                      "restores": rep["restores"],
                      "fixed": pair["fixed"], "paged": pair["paged"],
                      "artifact": _save_artifact(rows)}))
    print("serve dry-run ok")


def main() -> None:
    if "--dry-run" in sys.argv:
        dry_run()
        return
    run_all(fast="--fast" in sys.argv)


if __name__ == "__main__":
    main()
