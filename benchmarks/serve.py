"""Serve-path benchmark: gang-scheduled vs persistent-slot continuous
batching (tokens/s, time-to-first-token, decode-step compile count).

    PYTHONPATH=src python -m benchmarks.serve [--fast] [--dry-run]

The sweep serves a varied-prompt-length request stream through both
schedulers at several queue depths (multiples of ``max_batch``) and emits
``serve`` table rows; ``--dry-run`` is the CI smoke — a few bucket-aligned
requests, asserting the continuous scheduler's temperature-0 outputs match
gang scheduling and that the fixed-shape decode step compiled exactly
once.
"""

from __future__ import annotations

import json
import sys
import time

MAX_BATCH = 4
MAX_NEW = 32
KV_LEN = 56
_VARIED_LENGTHS = (5, 9, 14, 7, 15, 6, 11, 13)   # buckets 8 / 16
# Per-request decode budgets: the wide spread is what exposes the gang
# convoy effect — every early finisher idles its slot until the gang's
# longest request (MAX_NEW steps) drains, while continuous refills it.
_VARIED_BUDGETS = (2, MAX_NEW, 3, 5)


def _build():
    import jax

    from repro.configs import get_model_config
    from repro.models import build_model

    cfg = get_model_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(n: int, equal_len: int = 0):
    import numpy as np

    from repro.serve import Request

    lengths = [equal_len or _VARIED_LENGTHS[i % len(_VARIED_LENGTHS)]
               for i in range(n)]
    return [Request(rid=i,
                    max_new_tokens=(MAX_NEW if equal_len else
                                    _VARIED_BUDGETS[i % len(_VARIED_BUDGETS)]),
                    prompt=np.asarray((np.arange(ln) + 3 * i) % 100,
                                      np.int32))
            for i, ln in enumerate(lengths)]


def _engine(cfg, model, params, scheduler: str, obs=None):
    from repro.configs.base import ServeConfig
    from repro.serve import Engine

    return Engine(model, params, cfg,
                  ServeConfig(max_batch=MAX_BATCH, max_new_tokens=MAX_NEW,
                              kv_cache_len=KV_LEN, scheduler=scheduler),
                  eos_id=-1, obs=obs)


def _serve(eng, make_reqs, repeats: int = 1):
    """Serve ``make_reqs()`` ``repeats`` times on a warm engine, reporting
    the best wall clock (per-request streams are rebuilt each repeat so
    outputs don't accumulate)."""
    best, done = float("inf"), []
    for _ in range(repeats):
        reqs = make_reqs()
        t0 = time.perf_counter()
        done = eng.run(reqs)
        best = min(best, time.perf_counter() - t0)
    toks = sum(len(r.out_tokens) for r in done)
    ttft = [r.t_first - t0 for r in done if r.t_first is not None]
    return done, {
        "tok_s": round(toks / best, 1),
        "ttft_ms_mean": round(1e3 * sum(ttft) / max(len(ttft), 1), 2),
        "ttft_ms_max": round(1e3 * max(ttft), 2) if ttft else 0.0,
        "decode_compiles": eng.decode_compile_count(),
        "wall_s": round(best, 3),
    }


def run_all(fast: bool = False) -> list[dict]:
    from repro.core.obs import CounterTimeline

    cfg, model, params = _build()
    depths = (2, 4) if fast else (2, 4, 8)       # × MAX_BATCH
    rows = []
    for scheduler in ("gang", "continuous"):
        # per-tick engine timeline, written next to the bench JSON
        timeline = CounterTimeline(source=f"bench-serve/{scheduler}")
        eng = _engine(cfg, model, params, scheduler, obs=timeline)
        eng.run(_requests(2 * MAX_BATCH))        # warm the compile caches
        for mult in depths:
            n = mult * MAX_BATCH
            _, stats = _serve(eng, lambda n=n: _requests(n), repeats=5)
            row = {"table": "serve", "scheduler": scheduler,
                   "queue_depth": n, "max_batch": MAX_BATCH,
                   "max_new_tokens": MAX_NEW, **stats}
            rows.append(row)
            print(json.dumps(row))
        path = timeline.save(f"runs/serve_{scheduler}_timeline.json")
        print(json.dumps({"table": "serve", "scheduler": scheduler,
                          "timeline": path,
                          "ticks": len(timeline.samples)}))
    return rows


def dry_run() -> None:
    """CI smoke: bucket-aligned stream through both schedulers must emit
    identical temperature-0 tokens, with exactly one decode compile on
    the continuous side, and the attached engine timeline must round-trip
    as a well-formed schema-versioned artifact."""
    from repro.core.obs import CounterTimeline

    cfg, model, params = _build()
    timeline = CounterTimeline(source="bench-serve/dryrun")
    done_c, stats_c = _serve(_engine(cfg, model, params, "continuous",
                                     obs=timeline),
                             lambda: _requests(6, equal_len=8))
    done_g, stats_g = _serve(_engine(cfg, model, params, "gang"),
                             lambda: _requests(6, equal_len=8))
    out_c = {r.rid: r.out_tokens for r in done_c}
    out_g = {r.rid: r.out_tokens for r in done_g}
    assert out_c == out_g, "continuous != gang at temperature 0"
    assert stats_c["decode_compiles"] == 1, stats_c
    path = timeline.save("runs/serve_dryrun_timeline.json")
    doc = CounterTimeline.load(path)             # validates the schema
    assert doc["samples"], "engine timeline captured no ticks"
    print(json.dumps({"table": "serve_dryrun", "requests": len(out_c),
                      "timeline": path, "ticks": len(doc["samples"]),
                      "continuous": stats_c, "gang": stats_g}))
    print("serve dry-run ok")


def main() -> None:
    if "--dry-run" in sys.argv:
        dry_run()
        return
    run_all(fast="--fast" in sys.argv)


if __name__ == "__main__":
    main()
